"""distributed_sort round plumbing + bitonic kv tie-break edge cases.

Correctness-critical branches that were previously untested: the odd-even
transposition partner tables (edge devices must idle, partners must pair up
symmetrically, for even AND odd device counts) and the tie-break rule of the
word-parallel kv bitonic sort (equal keys keep the self payload, so argsort
stays a permutation under heavy ties).
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import distributed_sort as ds
from repro.core import sort_api


# ---------------------------------------------------------------------------
# _round_permutation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n_dev", [1, 2, 3, 4, 5, 7, 8, 16])
@pytest.mark.parametrize("even_round", [True, False])
def test_round_permutation_is_symmetric_involution(n_dev, even_round):
    pairs = ds._round_permutation(n_dev, even_round)
    partner = {i: p for i, p in pairs}
    assert sorted(partner) == list(range(n_dev))
    for i, p in partner.items():
        assert 0 <= p < n_dev                  # never addresses off the mesh
        assert partner[p] == i                 # pairing is mutual


@pytest.mark.parametrize("n_dev", [2, 3, 4, 5, 8, 9])
def test_round_permutation_edge_idling(n_dev):
    even = dict(ds._round_permutation(n_dev, True))
    odd = dict(ds._round_permutation(n_dev, False))
    # odd rounds: device 0 idles; last device idles iff count is even
    assert odd[0] == 0
    assert (odd[n_dev - 1] == n_dev - 1) == (n_dev % 2 == 0)
    # even rounds: last device idles iff count is odd
    assert (even[n_dev - 1] == n_dev - 1) == (n_dev % 2 == 1)
    # non-edge devices all participate
    active_even = sum(1 for i, p in even.items() if p != i)
    active_odd = sum(1 for i, p in odd.items() if p != i)
    assert active_even == 2 * (n_dev // 2)
    assert active_odd == 2 * ((n_dev - 1) // 2)


def test_round_permutations_cover_all_adjacent_links():
    """Across one even+odd round pair every adjacent device link is used."""
    n_dev = 6
    links = set()
    for even_round in (True, False):
        for i, p in ds._round_permutation(n_dev, even_round):
            if p != i:
                links.add((min(i, p), max(i, p)))
    assert links == {(i, i + 1) for i in range(n_dev - 1)}


def test_odd_even_transposition_sorts_on_host():
    """Drive the round tables through a pure-numpy merge-split simulation:
    after n_dev rounds the shard concatenation must be globally sorted."""
    rng = np.random.default_rng(0)
    for n_dev in (2, 3, 4, 5, 8):
        shards = [np.sort(rng.standard_normal(16)) for _ in range(n_dev)]
        for r in range(n_dev):
            pairs = ds._round_permutation(n_dev, r % 2 == 0)
            for i, p in pairs:
                if p <= i:
                    continue
                both = np.sort(np.concatenate([shards[i], shards[p]]))
                shards[i], shards[p] = both[:16], both[16:]
        flat = np.concatenate(shards)
        np.testing.assert_array_equal(flat, np.sort(flat))


def test_bitonic_merge_halves():
    rng = np.random.default_rng(1)
    lo = jnp.asarray(np.sort(rng.standard_normal(32)), jnp.float32)
    hi = jnp.asarray(np.sort(rng.standard_normal(32)), jnp.float32)
    out_lo, out_hi = ds.bitonic_merge_halves(lo, hi)
    ref = np.sort(np.concatenate([np.array(lo), np.array(hi)]))
    np.testing.assert_array_equal(np.array(out_lo), ref[:32])
    np.testing.assert_array_equal(np.array(out_hi), ref[32:])


# ---------------------------------------------------------------------------
# bitonic kv tie-break
# ---------------------------------------------------------------------------

def test_bitonic_kv_constant_keys_keep_payload_permutation():
    keys = jnp.zeros((2, 16), jnp.float32)
    vals = jnp.broadcast_to(jnp.arange(16, dtype=jnp.int32), (2, 16))
    sk, sv = sort_api.bitonic_sort(keys, values=vals)
    np.testing.assert_array_equal(np.array(sk), np.zeros((2, 16)))
    # every payload survives exactly once (the tie rule never duplicates)
    np.testing.assert_array_equal(np.sort(np.array(sv), -1),
                                  np.broadcast_to(np.arange(16), (2, 16)))


@pytest.mark.parametrize("descending", [False, True])
def test_bitonic_kv_heavy_ties_valid_permutation(descending):
    rng = np.random.default_rng(2)
    keys = rng.integers(0, 3, size=(4, 33)).astype(np.int32)  # many ties
    vals = np.broadcast_to(np.arange(33, dtype=np.int32), (4, 33))
    sk, sv = sort_api.bitonic_sort(jnp.asarray(keys),
                                   values=jnp.asarray(vals),
                                   descending=descending)
    sk, sv = np.array(sk), np.array(sv)
    ref = np.sort(keys, -1)
    if descending:
        ref = np.flip(ref, -1)
    np.testing.assert_array_equal(sk, ref)
    np.testing.assert_array_equal(np.sort(sv, -1),
                                  np.broadcast_to(np.arange(33), (4, 33)))
    # payloads must point at positions holding their own key value
    np.testing.assert_array_equal(np.take_along_axis(keys, sv, -1), sk)


def test_argsort_pallas_routes_to_kernel_and_agrees():
    """Regression: method='pallas' used to silently fall through to the jnp
    path; it must hit the kv kernel and still produce a valid argsort."""
    x = jnp.asarray(np.random.default_rng(3).standard_normal((2, 37)),
                    jnp.float32)
    order = np.array(sort_api.argsort(x, method="pallas"))
    np.testing.assert_array_equal(
        np.take_along_axis(np.array(x), order, -1), np.sort(np.array(x), -1))


def test_argsort_imc_wide_keys_raise():
    """imc argsort packs (key, index) into one array word: 32-bit keys
    leave no index bits, so the composite path must refuse clearly (narrow
    keys work — see test_sort_conformance.test_imc_argsort_conformance)."""
    x = jnp.asarray(np.arange(8, dtype=np.uint32))
    with pytest.raises(ValueError, match="32-bit"):
        sort_api.argsort(x, method="imc")
    with pytest.raises(ValueError, match="method must be one of"):
        sort_api.argsort(x, method="nope")
