"""Gate-level CAS block: exhaustive + property validation."""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import cas, gates


def test_w4_program_structure_matches_paper():
    prog = gates.build_cas_program(4)
    assert prog.total_cycles == 28          # Table I total
    assert prog.compare_cycles == 18        # result @ c17, inverse @ c18
    assert prog.mux_cycles == 8
    assert prog.writeback_cycles == 2
    assert prog.n_rows == 22                # Fig. 5: 4 x 22 array


def test_w4_exhaustive_all_256_pairs():
    a = np.repeat(np.arange(16), 16)
    b = np.tile(np.arange(16), 16)
    r = cas.run_cas(a, b, width=4)
    np.testing.assert_array_equal(np.array(r.lo), np.minimum(a, b))
    np.testing.assert_array_equal(np.array(r.hi), np.maximum(a, b))
    assert r.cycles == 28


@given(st.sampled_from([2, 8, 16, 32]), st.integers(0, 2**32 - 1))
@settings(max_examples=40, deadline=None)
def test_wider_words_extrapolate(width, seed):
    rng = np.random.default_rng(seed)
    a = rng.integers(0, 2**width, 64, dtype=np.uint64).astype(np.uint32)
    b = rng.integers(0, 2**width, 64, dtype=np.uint64).astype(np.uint32)
    r = cas.run_cas(a, b, width=width)
    np.testing.assert_array_equal(np.array(r.lo), np.minimum(a, b))
    np.testing.assert_array_equal(np.array(r.hi), np.maximum(a, b))


def test_only_two_input_ops_used():
    """The 6T SRAM constraint: every op is 2-input NOR/AND (NOT and COPY are
    the constant-row derivations)."""
    from repro.core.imc_array import OpKind
    for w in (2, 4, 8):
        for op in gates.build_cas_program(w).ops:
            assert op.kind in (OpKind.NOR, OpKind.AND, OpKind.NOT,
                               OpKind.COPY)
