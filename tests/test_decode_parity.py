"""Prefill + decode must reproduce teacher-forced forward logits exactly
(the KV-cache / recurrent-state correctness invariant), for every family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_smoke_config
from repro.models import build

B, S = 2, 16


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_then_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    model = build(cfg, policy=None, remat=False)
    params, _ = model.init(jax.random.PRNGKey(0))
    tokens = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                cfg.vocab_size)
    batch = {"tokens": tokens}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.enc_seq, cfg.d_model)) * 0.1
        enc = model.impl.encode(params, batch["frames"])
        from repro.models import layers as L
        hid = model.impl.decode_hidden(params, tokens, enc)
        full = L.logits_from_hidden(hid, params["embed"], None, tie=True,
                                    true_vocab=cfg.vocab_size)
    else:
        hid, _ = model.impl.hidden_states(params, tokens)
        full = model.impl.logits(params, hid)

    lg, state = model.prefill(params, batch, max_len=S + 8)
    np.testing.assert_allclose(np.array(lg), np.array(full[:, -1]),
                               atol=3e-2, rtol=0)

    nxt = jnp.argmax(lg, -1)[:, None].astype(jnp.int32)
    lg2, state = model.decode_step(params, nxt, state)
    ext = jnp.concatenate([tokens, nxt], 1)
    if cfg.family == "encdec":
        hid2 = model.impl.decode_hidden(params, ext, enc)
        from repro.models import layers as L
        full2 = L.logits_from_hidden(hid2, params["embed"], None, tie=True,
                                     true_vocab=cfg.vocab_size)
    else:
        hid2, _ = model.impl.hidden_states(params, ext)
        full2 = model.impl.logits(params, hid2)
    np.testing.assert_allclose(np.array(lg2), np.array(full2[:, -1]),
                               atol=5e-2, rtol=0)
