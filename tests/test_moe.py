"""MoE: counting-sort routing, capacity semantics, gradient flow."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import MoEConfig
from repro.models import moe


def _setup(e=8, k=2, d=16, f=32, cf=8.0):
    cfg = MoEConfig(n_experts=e, top_k=k, d_ff_expert=f, capacity_factor=cf)
    params, specs = moe.init(jax.random.PRNGKey(0), d, cfg, "swiglu",
                             jnp.float32)
    return cfg, params


def test_moe_matches_dense_expert_computation():
    """With ample capacity the layer must equal the dense per-token mix."""
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 16)) * 0.5
    out, aux = moe.apply(params, x, cfg, "swiglu", None)

    # dense oracle: every expert on every token, mix with the same gates
    rl = jnp.einsum("bsd,de->bse", x, params["router"])
    probs = jax.nn.softmax(rl, -1)
    gv, gi = jax.lax.top_k(probs, cfg.top_k)
    gv = gv / gv.sum(-1, keepdims=True)
    h = jnp.einsum("bsd,edf->bsef", x, params["wi"])
    hg = jnp.einsum("bsd,edf->bsef", x, params["wg"])
    y_all = jnp.einsum("bsef,efd->bsed", jax.nn.silu(hg) * h, params["wo"])
    dense = sum(jnp.take_along_axis(
        y_all, gi[..., i:i + 1, None], axis=2)[:, :, 0]
        * gv[..., i:i + 1] for i in range(cfg.top_k))
    np.testing.assert_allclose(np.array(out), np.array(dense), rtol=2e-4,
                               atol=2e-4)
    assert float(aux["moe_lb_loss"]) > 0.0


def test_capacity_drops_are_bounded():
    cfg = MoEConfig(n_experts=4, top_k=1, d_ff_expert=8,
                    capacity_factor=1.0)
    params, _ = moe.init(jax.random.PRNGKey(0), 8, cfg, "swiglu",
                         jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, 8))
    out, _ = moe.apply(params, x, cfg, "swiglu", None)
    assert bool(jnp.all(jnp.isfinite(out)))


def test_moe_gradients_flow_to_all_parts():
    cfg, params = _setup()
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 16))

    def loss(p):
        out, aux = moe.apply(p, x, cfg, "swiglu", None)
        return jnp.sum(out ** 2) + 0.01 * aux["moe_lb_loss"]

    g = jax.grad(loss)(params)
    for name in ("router", "wi", "wo", "wg"):
        assert float(jnp.sum(jnp.abs(g[name]))) > 0.0, name


def test_counting_sort_rank_is_correct():
    """pos must equal the rank of each (token,expert) pair within its
    expert, in flat order — i.e. exactly what the bitonic argsort gives."""
    rng = np.random.default_rng(0)
    flat_e = rng.integers(0, 8, 64)
    onehot = jax.nn.one_hot(jnp.asarray(flat_e), 8, dtype=jnp.int32)
    pos = np.array(jnp.sum((jnp.cumsum(onehot, axis=0) - onehot) * onehot,
                           axis=-1))
    seen = {}
    for i, e in enumerate(flat_e):
        assert pos[i] == seen.get(e, 0)
        seen[e] = seen.get(e, 0) + 1
