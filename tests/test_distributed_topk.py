"""Distributed top-k: local select + ONE candidate all-gather, bit-exact.

The acceptance bar is ``jax.lax.top_k`` equality on the gathered array —
values AND indices (global positions, lowest-index-first on ties) — with
no full-array sort: the only collective that scales with the data is the
all-gather of D·min(k, m) candidate (key, index) pairs.

The in-process tests run on whatever devices this host offers (a 1-device
mesh degenerates to the local radix-select — still the full code path);
the subprocess test forces 8 simulated devices so every CI run covers
real D>1, and the TIER1_MULTIDEV job runs this whole file at D=8.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.sort as rsort
from repro.core import distributed_sort as ds
from repro.engine import samplesort


def _mesh():
    return jax.make_mesh((len(jax.devices()),), ("data",))


def test_sample_topk_matches_lax_bit_exactly():
    mesh = _mesh()
    rng = np.random.default_rng(0)
    for n in (17, 1003, 4096):
        for maker in (
            lambda: rng.standard_normal(n).astype(np.float32),
            lambda: rng.integers(0, 7, n).astype(np.int32),   # dup-heavy
            lambda: np.zeros(n, np.float32),                  # all-equal
        ):
            x = jnp.asarray(maker())
            for k in sorted({1, 64 if n >= 64 else n, n}):
                v, i = samplesort.sample_topk(x, k, mesh, "data")
                vr, ir = jax.lax.top_k(x, k)
                msg = f"n={n}/k={k}/{x.dtype}"
                np.testing.assert_array_equal(np.asarray(v), np.asarray(vr),
                                              err_msg=msg)
                np.testing.assert_array_equal(np.asarray(i), np.asarray(ir),
                                              err_msg=msg)


def test_distributed_topk_entry_and_front_door():
    mesh = _mesh()
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal(2000), jnp.float32)
    v, i = ds.distributed_topk(x, 50, mesh)
    vr, ir = jax.lax.top_k(x, 50)
    np.testing.assert_array_equal(np.asarray(v), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(i), np.asarray(ir))
    # spec front door: SortSpec(k=..., mesh=...) routes the candidate path
    v2, i2 = rsort.topk(x, 50, mesh=mesh, axis_name="data")
    np.testing.assert_array_equal(np.asarray(v2), np.asarray(vr))
    np.testing.assert_array_equal(np.asarray(i2), np.asarray(ir))


def test_sample_topk_validation():
    mesh = _mesh()
    x = jnp.asarray(np.arange(64, dtype=np.float32))
    with pytest.raises(ValueError, match="1 <= k <= n"):
        samplesort.sample_topk(x, 0, mesh)
    with pytest.raises(ValueError, match="1 <= k <= n"):
        samplesort.sample_topk(x, 65, mesh)
    with pytest.raises(ValueError, match="flat 1-D"):
        samplesort.sample_topk(jnp.zeros((2, 8), jnp.float32), 2, mesh)
    with pytest.raises(ValueError, match="keycodec dtype"):
        # bool has no order-preserving unsigned encoding (and float64
        # would silently truncate to f32 on the x64-disabled CI jax)
        samplesort.sample_topk(x > 0, 2, mesh)
    # mesh top-k specs reject the combinations the candidate path can't
    # express, at the spec layer
    from repro.core.sortspec import SortSpec
    with pytest.raises(ValueError, match="do not combine with k"):
        rsort.run(SortSpec(k=2, mesh=mesh, values=x), x)


def test_candidate_bytes_accounting():
    """The analytic ICI bill: O(D·k) candidates vs O(D·m) bucket exchange
    — the whole point of selection at mesh scale."""
    assert samplesort.topk_candidate_bytes_per_device(8, 64, 1 << 17, 4) \
        == 8 * 64 * 8
    # k > m clamps to the shard (the candidate pool is the whole array)
    assert samplesort.topk_candidate_bytes_per_device(8, 1 << 20, 1 << 10, 4) \
        == 8 * (1 << 10) * 8
    big_sort = samplesort.alltoall_bytes_per_device(8, 1 << 17, 4)
    big_topk = samplesort.topk_candidate_bytes_per_device(8, 64, 1 << 17, 4)
    assert big_topk * 100 < big_sort


def test_distributed_topk_8dev_subprocess():
    """Forced 8-device run: bit-exact lax.top_k equality at real D>1 over
    an uneven, duplicate-heavy array — ties crossing shard boundaries is
    exactly where a sloppy candidate merge would diverge."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.engine import samplesort
import repro.sort as rsort
mesh = jax.make_mesh((8,), ("data",))
assert len(jax.devices()) == 8
rng = np.random.default_rng(0)
x = rng.integers(0, 9, 1003).astype(np.int32)      # uneven + dup-heavy
for k in (1, 64, 500, 1003):
    v, i = samplesort.sample_topk(jnp.asarray(x), k, mesh, "data")
    vr, ir = jax.lax.top_k(jnp.asarray(x), k)
    assert (np.asarray(v) == np.asarray(vr)).all(), k
    assert (np.asarray(i) == np.asarray(ir)).all(), k
# explicitly sharded input through the spec front door
xf = rng.standard_normal(8 * 512).astype(np.float32)
xs = jax.device_put(jnp.asarray(xf), NamedSharding(mesh, P("data")))
v, i = rsort.topk(xs, 64, mesh=mesh)
vr, ir = jax.lax.top_k(jnp.asarray(xf), 64)
assert (np.asarray(v) == np.asarray(vr)).all()
assert (np.asarray(i) == np.asarray(ir)).all()
print("DIST_TOPK_8DEV_OK")
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(repo, "src")}
    env.pop("XLA_FLAGS", None)        # the subprocess pins its own count
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=420)
    assert "DIST_TOPK_8DEV_OK" in r.stdout, r.stderr[-2000:]
