"""REQUIRED per-arch smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and absence of NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config, get_smoke_config
from repro.models import build

B, S = 2, 16


def _batch(cfg, key=0):
    rng = np.random.default_rng(key)
    batch = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              dtype=jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)),
                              dtype=jnp.int32),
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.enc_seq, cfg.d_model)) * 0.1,
            dtype=jnp.float32)
    if cfg.vision_prefix:
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.vision_prefix, cfg.d_model)) * 0.1,
            dtype=jnp.bfloat16)
        batch["positions"] = jnp.broadcast_to(
            jnp.arange(S, dtype=jnp.int32), (3, B, S))
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    model = build(cfg, policy=None, remat=False)
    params, specs = model.init(jax.random.PRNGKey(0))
    assert jax.tree.structure(params) == jax.tree.structure(
        specs, is_leaf=lambda x: not isinstance(x, (dict, list)))
    batch = _batch(cfg)

    loss, aux = model.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch

    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    for path, g in jax.tree_util.tree_flatten_with_path(grads)[0]:
        assert bool(jnp.all(jnp.isfinite(g))), (arch, path)

    if cfg.family != "encdec":
        hid, _ = model.impl.hidden_states(params, batch["tokens"],
                                          batch.get("positions"),
                                          batch.get("vision_embeds"))
        assert hid.shape == (B, S, cfg.d_model)
        logits = model.impl.logits(params, hid)
        assert logits.shape == (B, S, cfg.padded_vocab)
        assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_configs_have_published_dims(arch):
    cfg = get_config(arch)
    published = {
        "whisper_tiny": (4, 384, 6, 6, 1536, 51865),
        "deepseek_67b": (95, 8192, 64, 8, 22016, 102400),
        "minitron_4b": (32, 3072, 24, 8, 9216, 256000),
        "gemma_2b": (18, 2048, 8, 1, 16384, 256000),
        "nemotron_4_340b": (96, 18432, 96, 8, 73728, 256000),
        "moonshot_v1_16b": (48, 2048, 16, 16, 11264, 163840),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "recurrentgemma_2b": (26, 2560, 10, 1, 7680, 256000),
        "qwen2_vl_72b": (80, 8192, 64, 8, 29568, 152064),
        "mamba2_13b": (48, 2048, 1, 1, 0, 50280),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_ff,
           cfg.vocab_size)
    assert got == published


def test_moe_expert_configs():
    m = get_config("moonshot_v1_16b").moe
    assert (m.n_experts, m.top_k, m.d_ff_expert) == (64, 6, 1408)
    d = get_config("dbrx_132b").moe
    assert (d.n_experts, d.top_k, d.d_ff_expert) == (16, 4, 10752)


def test_param_counts_in_published_ballpark():
    # active params should land within ~20% of the published totals
    expect = {"deepseek_67b": 67e9, "nemotron_4_340b": 340e9,
              "dbrx_132b": 132e9, "qwen2_vl_72b": 72e9,
              "mamba2_13b": 1.3e9, "gemma_2b": 2.5e9}
    for arch, target in expect.items():
        n = get_config(arch).n_params()
        assert 0.75 * target < n < 1.35 * target, (arch, n, target)
