"""Distributed relational ops: mesh dedup/group-by == single-device.

The composition argument under test: the sample-sort splitter round
co-locates equal keys on one device, so the op's local post-pass (boundary
mask -> compaction -> segment reduce) IS the global answer — no second
collective.  Acceptance is element-exact agreement with the single-device
op (and through it the numpy reference).

The in-process tests run on whatever devices this host offers (a 1-device
mesh still exercises the full mesh code path); the subprocess test forces
8 simulated devices so every CI run covers real D>1, and the
TIER1_MULTIDEV job runs this whole file at D=8.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro.relational as rel


def _mesh():
    return jax.make_mesh((len(jax.devices()),), ("data",))


def _cases():
    rng = np.random.default_rng(0)
    return [
        rng.integers(-40, 40, 1003).astype(np.int32),   # uneven n
        rng.integers(0, 5, 2048).astype(np.int32),      # dup-heavy
        np.full(512, 7, np.int32),                      # all-equal
        np.where(rng.random(777) < 0.4, -0.0,
                 rng.integers(0, 9, 777)).astype(np.float32),  # signed zeros
    ]


def test_mesh_unique_matches_single_device():
    mesh = _mesh()
    for x in _cases():
        u = rel.unique(x, mesh=mesh, return_inverse=True,
                       return_counts=True)
        ref_v, ref_inv, ref_c = np.unique(x, return_inverse=True,
                                          return_counts=True)
        m = int(u.n_unique)
        msg = f"{x.dtype}/n={len(x)}"
        assert m == len(ref_v), msg
        np.testing.assert_array_equal(np.asarray(u.values[:m]), ref_v,
                                      err_msg=msg)
        np.testing.assert_array_equal(np.asarray(u.inverse), ref_inv,
                                      err_msg=msg)
        np.testing.assert_array_equal(np.asarray(u.counts[:m]), ref_c,
                                      err_msg=msg)


def test_mesh_group_by_matches_single_device():
    mesh = _mesh()
    rng = np.random.default_rng(1)
    for k in _cases():
        v = rng.integers(0, 100, len(k)).astype(np.int32)
        got = rel.group_by(k, v, agg=("sum", "min", "max", "count"),
                           mesh=mesh)
        want = rel.group_by(k, v, agg=("sum", "min", "max", "count"))
        g = int(got.n_groups)
        msg = f"{k.dtype}/n={len(k)}"
        assert g == int(want.n_groups), msg
        np.testing.assert_array_equal(np.asarray(got.keys[:g]),
                                      np.asarray(want.keys[:g]),
                                      err_msg=msg)
        for a, b in zip(got.aggregates, want.aggregates):
            np.testing.assert_array_equal(np.asarray(a[:g]),
                                          np.asarray(b[:g]), err_msg=msg)


def test_mesh_spec_validation():
    mesh = _mesh()
    x = jnp.zeros(16, jnp.int32)
    from repro.relational.relspec import RelSpec
    with pytest.raises(ValueError, match="has none"):
        RelSpec(op="rle", mesh=mesh).canonical(x)
    with pytest.raises(ValueError, match="'auto' or 'distributed'"):
        rel.unique(x, mesh=mesh, method="radix")
    with pytest.raises(ValueError, match="not in mesh axes"):
        rel.unique(x, mesh=mesh, axis_name="model")
    with pytest.raises(ValueError, match="keycodec dtype"):
        rel.unique(jnp.zeros(8, bool), mesh=mesh)


@pytest.mark.slow          # ~25s: 8-device subprocess restart + suite
def test_distributed_relational_8dev_subprocess():
    """Forced 8-device run: dedup and group-by agree with the
    single-device ops over uneven, duplicate-heavy, and signed-zero
    columns — equal keys straddling shard boundaries is exactly where a
    sloppy splitter round would break the local-op == global-op claim."""
    code = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
import repro.relational as rel
mesh = jax.make_mesh((8,), ("data",))
assert len(jax.devices()) == 8
rng = np.random.default_rng(0)
cases = [
    rng.integers(-40, 40, 1003).astype(np.int32),
    rng.integers(0, 5, 2048).astype(np.int32),
    np.full(512, 7, np.int32),
    np.where(rng.random(777) < 0.4, -0.0,
             rng.integers(0, 9, 777)).astype(np.float32),
]
for x in cases:
    u = rel.unique(x, mesh=mesh, return_counts=True)
    ref_v, ref_c = np.unique(x, return_counts=True)
    m = int(u.n_unique)
    assert m == len(ref_v), (x.dtype, m, len(ref_v))
    assert (np.asarray(u.values[:m]) == ref_v).all()
    assert (np.asarray(u.counts[:m]) == ref_c).all()
    v = rng.integers(0, 100, len(x)).astype(np.int32)
    got = rel.group_by(x, v, agg=("sum", "count"), mesh=mesh)
    want = rel.group_by(x, v, agg=("sum", "count"))
    g = int(got.n_groups)
    assert g == int(want.n_groups)
    assert (np.asarray(got.keys[:g]) == np.asarray(want.keys[:g])).all()
    for a, b in zip(got.aggregates, want.aggregates):
        assert (np.asarray(a[:g]) == np.asarray(b[:g])).all()
print("DIST_RELATIONAL_8DEV_OK")
"""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = {**os.environ, "PYTHONPATH": os.path.join(repo, "src")}
    env.pop("XLA_FLAGS", None)        # the subprocess pins its own count
    r = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=420)
    assert "DIST_RELATIONAL_8DEV_OK" in r.stdout, r.stderr[-2000:]
