"""Optimizers + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import optimizers as opt_lib
from repro.optim.grad_compress import CompressorConfig, make_compressor


def _rosenbrockish(params):
    x = params["x"]
    return jnp.sum((x - 1.5) ** 2) + jnp.sum(jnp.sin(x) ** 2) * 0.1


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizers_descend(name):
    sched = opt_lib.cosine_schedule(1e-1, warmup=5, total=100)
    opt = (opt_lib.adamw(sched, weight_decay=0.0) if name == "adamw"
           else opt_lib.adafactor(sched))
    params = {"x": jnp.linspace(-2, 2, 256).reshape(2, 128)}
    state = opt.init(params)
    l0 = float(_rosenbrockish(params))
    for step in range(60):
        g = jax.grad(_rosenbrockish)(params)
        state, info = opt.update(g, state, jnp.asarray(step))
        params = opt_lib.cast_like_params(state["master"], params)
    assert float(_rosenbrockish(params)) < 0.5 * l0


def test_adafactor_memory_is_sublinear():
    params = {"w": jnp.zeros((512, 256))}
    sched = opt_lib.cosine_schedule(1e-2, 1, 10)
    state = opt_lib.adafactor(sched).init(params)
    v = state["v"]["w"]
    assert set(v) == {"vr", "vc"}
    assert v["vr"].shape == (512,) and v["vc"].shape == (256,)


def test_adafactor_state_specs_follow_factoring():
    from jax.sharding import PartitionSpec as P
    sched = opt_lib.cosine_schedule(1e-2, 1, 10)
    opt = opt_lib.adafactor(sched)
    specs = {"w": P("data", "model"), "b": P(None)}
    abstract = {"w": jax.ShapeDtypeStruct((512, 256), jnp.float32),
                "b": jax.ShapeDtypeStruct((256,), jnp.float32)}
    ss = opt.state_specs(specs, abstract)
    assert ss["v"]["w"]["vr"] == P("data")
    assert ss["v"]["w"]["vc"] == P("model")
    assert ss["v"]["b"] == {"v": P(None)}


def test_grad_clip_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0))
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


@pytest.mark.parametrize("codec", ["int8", "topk"])
def test_error_feedback_compression_converges(codec):
    """Error feedback: the ACCUMULATED compressed signal tracks the
    accumulated true gradient (bias does not build up)."""
    cfg = CompressorConfig(codec=codec, topk_frac=0.25)
    init_state, apply = make_compressor(cfg)
    params = {"w": jnp.zeros((64,))}
    state = init_state(params)
    rng = np.random.default_rng(0)
    g_true_sum = np.zeros(64)
    g_sent_sum = np.zeros(64)
    base = rng.standard_normal(64)
    for _ in range(50):
        g = {"w": jnp.asarray(base + 0.1 * rng.standard_normal(64),
                              dtype=jnp.float32)}
        g_true_sum += np.array(g["w"])
        sent, state = apply(g, state)
        g_sent_sum += np.array(sent["w"])
    # residual error is bounded by one step's worth, not 50 steps' worth
    err = np.abs(g_sent_sum - g_true_sum).max()
    assert err < 2.0 * np.abs(base).max()


def test_int8_roundtrip_quantization_error():
    from repro.optim.grad_compress import _int8_roundtrip
    g = jnp.asarray(np.random.default_rng(1).standard_normal(1000),
                    dtype=jnp.float32)
    rt = _int8_roundtrip(g)
    scale = float(jnp.max(jnp.abs(g))) / 127
    assert float(jnp.max(jnp.abs(rt - g))) <= scale * 0.5 + 1e-6
