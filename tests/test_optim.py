"""Optimizers + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import optimizers as opt_lib
from repro.optim.grad_compress import CompressorConfig, make_compressor


def _rosenbrockish(params):
    x = params["x"]
    return jnp.sum((x - 1.5) ** 2) + jnp.sum(jnp.sin(x) ** 2) * 0.1


@pytest.mark.parametrize("name", ["adamw", "adafactor"])
def test_optimizers_descend(name):
    sched = opt_lib.cosine_schedule(1e-1, warmup=5, total=100)
    opt = (opt_lib.adamw(sched, weight_decay=0.0) if name == "adamw"
           else opt_lib.adafactor(sched))
    params = {"x": jnp.linspace(-2, 2, 256).reshape(2, 128)}
    state = opt.init(params)
    l0 = float(_rosenbrockish(params))
    for step in range(60):
        g = jax.grad(_rosenbrockish)(params)
        state, info = opt.update(g, state, jnp.asarray(step))
        params = opt_lib.cast_like_params(state["master"], params)
    assert float(_rosenbrockish(params)) < 0.5 * l0


def test_adafactor_memory_is_sublinear():
    params = {"w": jnp.zeros((512, 256))}
    sched = opt_lib.cosine_schedule(1e-2, 1, 10)
    state = opt_lib.adafactor(sched).init(params)
    v = state["v"]["w"]
    assert set(v) == {"vr", "vc"}
    assert v["vr"].shape == (512,) and v["vc"].shape == (256,)


def test_adafactor_state_specs_follow_factoring():
    from jax.sharding import PartitionSpec as P
    sched = opt_lib.cosine_schedule(1e-2, 1, 10)
    opt = opt_lib.adafactor(sched)
    specs = {"w": P("data", "model"), "b": P(None)}
    abstract = {"w": jax.ShapeDtypeStruct((512, 256), jnp.float32),
                "b": jax.ShapeDtypeStruct((256,), jnp.float32)}
    ss = opt.state_specs(specs, abstract)
    assert ss["v"]["w"]["vr"] == P("data")
    assert ss["v"]["w"]["vc"] == P("model")
    assert ss["v"]["b"] == {"v": P(None)}


def test_grad_clip_global_norm():
    g = {"a": jnp.full((10,), 10.0)}
    clipped, norm = opt_lib.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(1000.0))
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


@pytest.mark.parametrize("codec", ["int8", "topk"])
def test_error_feedback_compression_converges(codec):
    """Error feedback: the ACCUMULATED compressed signal tracks the
    accumulated true gradient (bias does not build up)."""
    cfg = CompressorConfig(codec=codec, topk_frac=0.25)
    init_state, apply = make_compressor(cfg)
    params = {"w": jnp.zeros((64,))}
    state = init_state(params)
    rng = np.random.default_rng(0)
    g_true_sum = np.zeros(64)
    g_sent_sum = np.zeros(64)
    base = rng.standard_normal(64)
    for _ in range(50):
        g = {"w": jnp.asarray(base + 0.1 * rng.standard_normal(64),
                              dtype=jnp.float32)}
        g_true_sum += np.array(g["w"])
        sent, state = apply(g, state)
        g_sent_sum += np.array(sent["w"])
    # residual error is bounded by one step's worth, not 50 steps' worth
    err = np.abs(g_sent_sum - g_true_sum).max()
    assert err < 2.0 * np.abs(base).max()


def test_int8_roundtrip_quantization_error():
    from repro.optim.grad_compress import _int8_roundtrip
    g = jnp.asarray(np.random.default_rng(1).standard_normal(1000),
                    dtype=jnp.float32)
    rt = _int8_roundtrip(g)
    scale = float(jnp.max(jnp.abs(g))) / 127
    assert float(jnp.max(jnp.abs(rt - g))) <= scale * 0.5 + 1e-6


# ---------------------------------------------------------------------------
# exact-k compression guarantee (the threshold-mask bug class)
# ---------------------------------------------------------------------------

def _kept_lanes(g, out):
    """Lanes the codec kept: where the output reproduces the input AND the
    selection actually happened (nonzero output, or provably selected)."""
    return np.flatnonzero(np.asarray(out) != 0.0)


def test_topk_roundtrip_sparse_zero_tail_regression():
    """Repro from the bug report: when the k-th largest |g| is 0.0, the old
    ``|g| >= thresh`` mask was all-true — compression silently OFF.  The
    exact-k scatter keeps only the k genuine lanes."""
    from repro.optim.grad_compress import _topk_roundtrip
    g = jnp.asarray([2.0, 0.0, 0.0, 0.0, -1.0, 0.0, 0.0, 0.0], jnp.float32)
    out = _topk_roundtrip(g, 0.25, "auto")          # k = 2
    np.testing.assert_array_equal(
        np.asarray(out), [2.0, 0.0, 0.0, 0.0, -1.0, 0.0, 0.0, 0.0])
    # fully sparse input: k zero lanes "kept", everything still zero —
    # but crucially nothing beyond the budget leaks through
    out0 = _topk_roundtrip(jnp.zeros(8, jnp.float32), 0.25, "auto")
    np.testing.assert_array_equal(np.asarray(out0), np.zeros(8))


def test_topk_roundtrip_all_equal_tie_budget_regression():
    """Repro from the bug report: frac=0.25 over 8 equal values kept all 8
    under the threshold mask.  Exact-k keeps exactly 2 (lowest indices —
    the documented tie convention)."""
    from repro.optim.grad_compress import _topk_roundtrip
    g = jnp.full((8,), 3.0, jnp.float32)
    out = _topk_roundtrip(g, 0.25, "auto")
    np.testing.assert_array_equal(
        np.asarray(out), [3.0, 3.0, 0.0, 0.0, 0.0, 0.0, 0.0, 0.0])


def test_topk_roundtrip_exact_k_property():
    """Property sweep over random sparsity patterns: the roundtrip output
    always equals the reference exact-k scatter built from jax.lax.top_k
    (so exactly k lanes survive, ties resolved lowest-index-first), and
    wire_bytes bills for precisely that k."""
    from repro.optim.grad_compress import (_topk_roundtrip, topk_budget,
                                           wire_bytes)
    rng = np.random.default_rng(7)
    for trial in range(20):
        n = int(rng.integers(4, 200))
        frac = float(rng.uniform(0.05, 0.9))
        sparsity = float(rng.uniform(0.0, 1.0))
        g_np = rng.standard_normal(n)
        g_np[rng.random(n) < sparsity] = 0.0
        if rng.random() < 0.3:                     # tie floods
            g_np = np.round(g_np)
        g = jnp.asarray(g_np, jnp.float32)
        k = topk_budget(n, frac)
        out = _topk_roundtrip(g, frac, "auto")
        _, idx = jax.lax.top_k(jnp.abs(g), k)
        ref = jnp.zeros_like(g).at[idx].set(g[idx])
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref),
                                      err_msg=f"trial={trial} n={n} k={k}")
        assert len(_kept_lanes(g, out)) <= k
        assert wire_bytes(n, "topk", frac) == k * 8
