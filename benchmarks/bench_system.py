"""System-level benchmarks: smoke-scale train/decode step times per arch,
MoE routing throughput, and the roofline summary from the dry-run records.
"""
from __future__ import annotations

import json
import pathlib
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ARCH_IDS, ShapeSpec, get_smoke_config
from repro.launch import steps as steps_lib
from repro.models import build

RESULTS = pathlib.Path(__file__).resolve().parents[1] / "results"


def _time(fn, reps=3):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def train_steps():
    rows = []
    for arch in ARCH_IDS:
        cfg = get_smoke_config(arch)
        model = build(cfg, policy=None, remat=False)
        params, _ = model.init(jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (2, 32)), dtype=jnp.int32)}
        batch["labels"] = batch["tokens"]
        if cfg.family == "encdec":
            batch["frames"] = jnp.asarray(
                rng.standard_normal((2, cfg.enc_seq, cfg.d_model)) * 0.1,
                dtype=jnp.float32)
        if cfg.vision_prefix:
            batch["vision_embeds"] = jnp.asarray(
                rng.standard_normal((2, cfg.vision_prefix, cfg.d_model))
                * 0.1, dtype=jnp.bfloat16)
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(32, dtype=jnp.int32), (3, 2, 32))
        shape = ShapeSpec("b", 32, 2, "train")
        fn, opt = steps_lib.make_train_step(model, cfg, shape, None)
        st = opt.init(params)
        jitted = jax.jit(fn)
        us = _time(lambda: jax.block_until_ready(
            jitted(params, st, jnp.asarray(0), batch)))
        rows.append((f"train_step.{arch}.smoke", round(us, 0), 64))
    return rows


def decode_steps():
    rows = []
    for arch in ("gemma_2b", "moonshot_v1_16b", "mamba2_13b",
                 "recurrentgemma_2b"):
        cfg = get_smoke_config(arch)
        model = build(cfg, policy=None, remat=False)
        params, _ = model.init(jax.random.PRNGKey(0))
        tok = jnp.zeros((4, 1), jnp.int32)
        state = model.decode_state(4, 64)
        step = jax.jit(model.decode_step)
        us = _time(lambda: jax.block_until_ready(step(params, tok, state)))
        rows.append((f"decode_step.{arch}.smoke", round(us, 0), 4))
    return rows


def roofline_summary():
    rows = []
    rl = RESULTS / "roofline.json"
    if rl.exists():
        for r in json.loads(rl.read_text()):
            if r["mesh"] != "16x16":
                continue
            rows.append((f"roofline.{r['arch']}.{r['shape']}.dominant_"
                         f"{r['dominant']}", 0.0,
                         round(max(r['t_compute_s'], r['t_memory_s'],
                                   r['t_collective_s']), 4)))
    return rows


def run():
    return train_steps() + decode_steps() + roofline_summary()
