"""Emit BENCH_sort.json — the canonical perf-trajectory artifact.

One JSON document per run, schema ``repro.bench.sort/v2``: a probe grid of
(op, n) bench points, and for each point every candidate backend's measured
warm ns next to its analytic ``cost_model.bytes_moved`` accounting (the
software analogue of the paper's Table I/II temp-row cycle counts), plus
the ``auto`` plan the cost-model planner actually picked — its backend,
predicted ns, measured ns, and the predicted-vs-measured
``cost_model_error`` ratio.

v2 adds a top-level ``profile`` block recording the tuning provenance the
run was planned under (``repro.core.tuning``): the device fingerprint, the
profile source (default / calibrated / persisted), the tuned kernel
parameters, and whether a persisted profile exists for this fingerprint —
``scripts/bench_gate.py`` hard-fails (even under ``--warn-only``) when it
does, because measured constants remove the only excuse for ``auto``
missing the best backend.

The point of the artifact is the *trajectory*: successive runs (CI uploads
one per commit) show whether ``auto`` keeps tracking the best measured
backend as the planner, kernels, and calibration evolve.
``scripts/bench_gate.py`` enforces the invariant at every point:
``auto.ns <= factor * best.ns``.

  PYTHONPATH=src python -m benchmarks.emit_bench --out benchmarks/BENCH_sort.json
  PYTHONPATH=src python -m benchmarks.emit_bench --quick   # CI probe grid

Schema (one point)::

  {"name": "sort.n65536", "op": "sort", "n": 65536, "k": null,
   "dtype": "float32",
   "backends": {"xla": {"ns": ..., "bytes_moved": ...}, ...},
   "auto":     {"backend": "xla", "ns": ..., "predicted_ns": ...,
                "cost_model_error": ..., "plan": {...}},
   "best":     {"backend": "xla", "ns": ...}}
"""
from __future__ import annotations

import argparse
import json
import pathlib
import time

import numpy as np

SCHEMA = "repro.bench.sort/v2"

QUICK_SIZES = (1024, 4096)
DEFAULT_SIZES = (4096, 65536)
TOPK_K = 64


def _finite(v):
    """inf/nan -> None so the document stays strict JSON."""
    if v is None or isinstance(v, str):
        return v
    v = float(v)
    return v if v == v and abs(v) != float("inf") else None


def _time_warm_ns(fn, x, reps: int) -> float:
    """Mean warm ns/call of ``jit(fn)`` (first call compiles, untimed)."""
    import jax
    f = jax.jit(fn)
    jax.block_until_ready(f(x))
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(x))
    return (time.perf_counter() - t0) / reps * 1e9


def _sort_candidates():
    import jax
    names = ["xla", "merge"]
    if jax.default_backend() == "tpu":
        names += ["pallas", "radix"]   # interpret mode is ~300x off-TPU
    return names


def _plan_dict(plan):
    return {"method": plan.method, "run_len": plan.run_len,
            "run_method": plan.run_method,
            "merge_backend": plan.merge_backend,
            "costs": {m: _finite(c) for m, c in sorted(plan.costs.items())}}


def _point(name, op, n, k, measured, auto_ns, plan, dtype="float32"):
    best = min(measured, key=lambda m: measured[m]["ns"])
    predicted = _finite(plan.costs.get(plan.method))
    return {
        "name": name, "op": op, "n": n, "k": k, "dtype": dtype,
        "backends": measured,
        "auto": {"backend": plan.method, "ns": auto_ns,
                 "predicted_ns": predicted,
                 "cost_model_error": (auto_ns / predicted
                                      if predicted else None),
                 "plan": _plan_dict(plan)},
        "best": {"backend": best, "ns": measured[best]["ns"]},
    }


def collect(sizes=DEFAULT_SIZES, k: int = TOPK_K, reps: int = 3):
    """Measure the probe grid -> list of bench points."""
    import jax.numpy as jnp
    from repro import sort as rsort
    from repro.core import cost_model
    from repro.engine import planner

    rng = np.random.default_rng(0)
    points = []
    for n in sizes:
        x = jnp.asarray(rng.standard_normal((1, n)), jnp.float32)

        measured = {}
        for name in _sort_candidates():
            ns = _time_warm_ns(lambda v, m=name: rsort.sort(v, method=m),
                               x, reps)
            measured[name] = {"ns": ns,
                              "bytes_moved": cost_model.bytes_moved(name, n)}
        auto_ns = _time_warm_ns(lambda v: rsort.sort(v), x, reps)
        plan = planner.choose_cached(n, 1, jnp.float32)
        points.append(_point(f"sort.n{n}", "sort", n, None,
                             measured, auto_ns, plan))

        if n < k:
            continue
        measured = {}
        for name in ("xla", "select"):
            ns = _time_warm_ns(
                lambda v, m=name: rsort.topk(v, k, method=m), x, reps)
            measured[name] = {
                "ns": ns, "bytes_moved": cost_model.bytes_moved(name, n, k=k)}
        auto_ns = _time_warm_ns(lambda v: rsort.topk(v, k), x, reps)
        plan = planner.choose_cached(n, 1, jnp.float32, k=k)
        points.append(_point(f"topk.n{n}.k{k}", "topk", n, k,
                             measured, auto_ns, plan))
    return points


def collect_relational(sizes=DEFAULT_SIZES, reps: int = 3):
    """Optional relational probe points (``--relational``; OFF by default
    so the CI baseline grid is byte-stable): one ``unique.nN`` point per
    size, measuring each auto-dispatchable sort backbone under
    ``relational.unique`` plus the ``choose_relational`` auto pick —
    the same auto-tracks-best trajectory, one workload class up."""
    import jax.numpy as jnp
    from repro import relational as rel
    from repro.core import cost_model
    from repro.engine import planner

    rng = np.random.default_rng(0)
    points = []
    for n in sizes:
        x = jnp.asarray(rng.integers(0, max(2, n // 4), n), jnp.int32)
        measured = {}
        for name in _sort_candidates():
            ns = _time_warm_ns(
                lambda v, m=name: rel.unique(v, method=m).values, x, reps)
            measured[name] = {
                "ns": ns, "bytes_moved": cost_model.bytes_moved(name, n)}
        auto_ns = _time_warm_ns(lambda v: rel.unique(v).values, x, reps)
        plan = planner.choose_relational_cached("unique", n,
                                                dtype=jnp.int32)
        points.append(_point(f"unique.n{n}", "unique", n, None,
                             measured, auto_ns, plan, dtype="int32"))
    return points


def _profile_block() -> dict:
    """Tuning provenance for the document: which profile priced the plans
    this run measured, and whether a persisted one exists on this machine
    (the bench gate's hard-fail condition)."""
    from repro.core import tuning
    prof = tuning.active()
    return {"fingerprint": prof.fingerprint,
            "source": prof.source,
            "digit_bits": prof.digit_bits,
            "run_len": prof.run_len,
            "capacity_slack": prof.capacity_slack,
            "select_min_n": prof.select_min_n,
            "persisted": tuning.persisted_path(prof.fingerprint) is not None}


def document(points) -> dict:
    import jax
    return {"schema": SCHEMA,
            "backend": jax.default_backend(),
            "profile": _profile_block(),
            "points": points}


def write(points, path) -> pathlib.Path:
    path = pathlib.Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(document(points), indent=2, allow_nan=False)
                    + "\n")
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="benchmarks/BENCH_sort.json")
    ap.add_argument("--quick", action="store_true",
                    help="small CI probe grid (n <= 4096)")
    ap.add_argument("--sizes", default="",
                    help="comma-separated n values (overrides presets)")
    ap.add_argument("--reps", type=int, default=3)
    ap.add_argument("--relational", action="store_true",
                    help="append relational probe points (unique.nN); off "
                         "by default so the CI baseline grid is unchanged")
    args = ap.parse_args()
    if args.sizes:
        sizes = tuple(int(s) for s in args.sizes.split(","))
    else:
        sizes = QUICK_SIZES if args.quick else DEFAULT_SIZES
    points = collect(sizes, reps=args.reps)
    if args.relational:
        points += collect_relational(sizes, reps=args.reps)
    path = write(points, args.out)
    doc = json.loads(path.read_text())
    for p in doc["points"]:
        print(f"[emit_bench] {p['name']}: auto={p['auto']['backend']} "
              f"{p['auto']['ns']/1e3:.1f}us  best={p['best']['backend']} "
              f"{p['best']['ns']/1e3:.1f}us")
    print(f"[emit_bench] wrote {path} ({len(doc['points'])} points)")


if __name__ == "__main__":
    main()
