"""Benchmarks reproducing the paper's tables and figures.

table1  — operation-cycle counts (CAS block + complete 8-input unit)
table2  — latency / throughput / operating frequency
fig8    — comparison vs MemSort [7] and the off-memory path: cycles (a),
          latency (b), memory bits (c)
fig7    — the simulation-waveform scenario (A=1000, B=0001) re-executed on
          the cycle-accurate array

Each prints ``name,us_per_call,derived`` CSV rows (us_per_call measures the
*simulator's* host cost; the derived column carries the paper-comparable
quantity).
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import cas, cost_model
from repro.core.sorter import sort_in_memory


def _time(fn, reps=3):
    fn()  # warm
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def table1():
    rows = []
    counts = cost_model.TABLE1_CAS_OPS
    totals = cost_model.stage_op_totals(8)
    for op in ("NOR", "NOT", "AND", "COPY"):
        rows.append((f"table1.cas.{op}", 0.0, counts[op]))
        rows.append((f"table1.stage8.{op}", 0.0, totals[op]))
    rows.append(("table1.cas.total", 0.0, sum(counts.values())))
    rows.append(("table1.stage8.total", 0.0, sum(totals.values())))
    return rows


def table2():
    us = _time(lambda: sort_in_memory(
        np.arange(8, dtype=np.uint32)[None], width=4))
    return [
        ("table2.latency_ns", us, cost_model.sort_latency_ns(8)),
        ("table2.throughput_gops", us, round(cost_model.throughput_gops(8), 2)),
        ("table2.frequency_ghz", 0.0, round(cost_model.OPERATING_FREQ_GHZ, 2)),
    ]


def fig8():
    ours_cyc = cost_model.sort_cycles(8)
    mem_cyc = cost_model.memsort_cycles(8)
    ours_lat = cost_model.sort_latency_ns(8)
    mem_lat = cost_model.memsort_latency_ns(8)
    bits = cost_model.memory_bits(8)
    return [
        ("fig8a.cycles.ours", 0.0, ours_cyc),
        ("fig8a.cycles.memsort", 0.0, round(mem_cyc, 1)),
        ("fig8a.cycle_ratio", 0.0, round(mem_cyc / ours_cyc, 3)),
        ("fig8b.latency_ns.ours", 0.0, ours_lat),
        ("fig8b.latency_ns.memsort", 0.0, round(mem_lat, 1)),
        ("fig8b.latency_ratio", 0.0, round(mem_lat / ours_lat, 2)),
        ("fig8b.off_memory_ratio", 0.0,
         round(cost_model.off_memory_latency_ns(8) / ours_lat, 2)),
        ("fig8c.memory_bits.ours", 0.0, bits),
        ("fig8c.bubble_sort_comparisons", 0.0,
         cost_model.bubble_sort_comparisons(8)),
    ]


def fig7():
    def run():
        r = cas.run_cas(np.array([0b1000]), np.array([0b0001]), width=4)
        return int(r.lo[0]), int(r.hi[0])
    us = _time(run)
    lo, hi = run()
    assert (lo, hi) == (0b0001, 0b1000)
    return [("fig7.waveform_cas.min", us, lo),
            ("fig7.waveform_cas.max", us, hi)]


def run():
    rows = []
    for fn in (table1, table2, fig8, fig7):
        rows.extend(fn())
    return rows
