"""Sorting-backend benchmark: the paper-faithful path vs word-parallel vs
XLA, across sizes — quantifies the beyond-paper speedup of lifting the
bit-serial constraint (DESIGN.md §2) on the actual execution substrate.

Also scales the paper's cost model over N and W (cycles + ns on the 65nm
SRAM target) so the hardware and software views sit side by side.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import cost_model, sort_api
from repro.core.sorter import sort_in_memory


def _time(fn, reps=5):
    fn()
    t0 = time.perf_counter()
    for _ in range(reps):
        fn()
    return (time.perf_counter() - t0) / reps * 1e6


def run():
    rows = []
    rng = np.random.default_rng(0)

    # software backends over vector batches
    for n in (64, 1024, 8192):
        x = jnp.asarray(rng.standard_normal((32, n)), dtype=jnp.float32)
        for method in ("xla", "bitonic", "pallas"):
            f = jax.jit(lambda v, m=method: sort_api.sort(v, method=m))
            us = _time(lambda: f(x).block_until_ready())
            rows.append((f"sort.{method}.n{n}", round(us, 1), n))

    # faithful bit-serial simulation (small n: it simulates every cycle)
    v8 = rng.integers(0, 16, size=(32, 8)).astype(np.uint32)
    us = _time(lambda: np.asarray(sort_in_memory(v8, width=4).values))
    rows.append(("sort.imc_sim.n8", round(us, 1),
                 cost_model.sort_cycles(8)))

    # top-k for routing shapes (the MoE path)
    for e, k in ((64, 6), (16, 4)):
        probs = jnp.asarray(rng.random((4096, e)), dtype=jnp.float32)
        for method in ("xla", "bitonic", "pallas"):
            f = jax.jit(lambda v, m=method: sort_api.topk(v, k, method=m)[0])
            us = _time(lambda: f(probs).block_until_ready())
            rows.append((f"topk.{method}.e{e}k{k}", round(us, 1), e))

    # hardware cost model scaling (cycles on the 65nm target)
    for n in (8, 16, 64, 256):
        rows.append((f"imc.cycles.n{n}w4", 0.0, cost_model.sort_cycles(n, 4)))
        rows.append((f"imc.latency_ns.n{n}w4", 0.0,
                     round(cost_model.sort_latency_ns(n, 4), 1)))
    for w in (2, 4, 8, 16):
        rows.append((f"imc.cas_cycles.w{w}", 0.0,
                     cost_model.cas_cycles(w, use_paper_counts=False)))
    return rows
