"""Engine benchmark: merge engine vs whole-array pallas-bitonic vs XLA.

Sweeps n from one-VMEM-tile scale to millions of elements and, for each of

  * ``xla``            jnp.sort (the off-memory reference),
  * ``pallas-bitonic`` the whole-array in-VMEM network (O(n log^2 n) CAS),
  * ``merge-engine``   tiled runs + merge-path merge tree (O(n log n)),
  * ``radix``          keycodec + Pallas LSD radix sort (O(n·b)),
  * ``auto``           whatever the planner dispatches to,

records TWO latencies:

  ``cold_ms``   first call: trace + compile + run.  The honest cost of a
                one-shot sort at a new size — the analytics workload the
                engine targets.  The whole-array network is size-specialised
                (every n compiles its own O(log^2 n)-substage program, and
                the build explodes with n), while the engine reuses
                tile-sized programs across n.
  ``warm_us``   steady-state per call after compilation.

Emits ``name,us_per_call,derived`` rows like the other suites (``cold`` rows
carry ms in the value column, labelled in the name).  The summary rows
compare merge vs pallas-bitonic at the largest n on both metrics.

A top-k leg (``topk_{sort,select,xla,auto}`` rows at k=64) compares the
sort-prefix path against the MSD radix-select backend and records the
measured select/sort crossover — the README "Selection" table and the
planner's sanity anchor.

With ``--devices D`` (or an externally set
``XLA_FLAGS=--xla_force_host_platform_device_count=D``) a distributed leg
also runs: single-round sample-sort vs D-round odd-even transposition over
the simulated mesh, plus the strategy ``planner.choose_distributed``
auto-selects per n — the measured crossover for the README table — and a
``topk_dist`` leg timing the mesh-global candidate-all-gather top-k.

  PYTHONPATH=src python -m benchmarks.bench_engine [--full] [--sizes 4096,...]
      [--devices 8]
"""
from __future__ import annotations

import time

import numpy as np

DEFAULT_SIZES = (4096, 65536, 1 << 20)
FULL_SIZES = (4096, 16384, 65536, 262144, 1 << 20, 1 << 22)

# interpret-mode radix pays the planner's ~300x penalty; cap its leg off-TPU
# so --full stays runnable (the crossover summary uses its largest timed n)
RADIX_INTERPRET_CAP = 65536


def _time_cold_warm(make_fn, x, reps: int):
    """(cold first-call seconds, warm mean seconds) for a fresh jit —
    tuple-valued fns (top-k) time their whole output tree."""
    import jax
    f = jax.jit(make_fn)
    t0 = time.perf_counter()
    jax.block_until_ready(f(x))
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(f(x))
    return cold, (time.perf_counter() - t0) / reps


def _time_cold_warm_eager(fn, x, reps: int):
    """Like ``_time_cold_warm`` but without an outer jit: the distributed
    entry point runs cached jitted phases around one host sync (the
    measured bucket capacity), so it is timed as called in practice."""
    import jax
    t0 = time.perf_counter()
    jax.block_until_ready(fn(x))
    cold = time.perf_counter() - t0
    t0 = time.perf_counter()
    for _ in range(reps):
        jax.block_until_ready(fn(x))
    return cold, (time.perf_counter() - t0) / reps


TOPK_K = 64


def run_topk(sizes=DEFAULT_SIZES, k=TOPK_K):
    """Selection vs sort-prefix: the ``k ≪ n`` workload class.

    Rows per n:

      * ``topk_sort``    the sort-prefix path: full descending stable
                         argsort + gather of the k prefix — what every
                         top-k consumer paid before the selection
                         subsystem existed.
      * ``topk_select``  the MSD radix-select backend (O(n·passes)).
      * ``topk_xla``     jax.lax.top_k, for context.
      * ``topk_auto``    the k-aware planner's pick (tagged with the
                         resolved backend).

    The summary row is the acceptance metric: select vs sort-prefix warm
    speedup at the largest n.
    """
    import jax.numpy as jnp
    from repro import engine, sort as rsort

    rows, summary = [], {}
    rng = np.random.default_rng(0)

    def sort_prefix(v):
        import jax.numpy as jnp
        order = jnp.argsort(v, axis=-1, stable=True, descending=True)
        return jnp.take_along_axis(v, order, -1)[..., :k], order[..., :k]

    legs = [
        ("topk_sort", sort_prefix),
        ("topk_select", lambda v: rsort.topk(v, k, method="select")),
        ("topk_xla", lambda v: rsort.topk(v, k, method="xla")),
        ("topk_auto", lambda v: rsort.topk(v, k)),
    ]
    for n in sizes:
        if n < k:
            continue
        x = jnp.asarray(rng.standard_normal((1, n)), jnp.float32)
        reps = 3 if n <= 65536 else 1
        for name, fn in legs:
            cold, warm = _time_cold_warm(fn, x, reps)
            tag = n
            if name == "topk_auto":
                plan = engine.choose_cached(n, 1, jnp.float32, k=k)
                tag = f"{n}:{plan.method}"
            rows.append((f"engine.{name}.cold_ms.n{n}.k{k}",
                         round(cold * 1e3, 1), tag))
            rows.append((f"engine.{name}.warm_us.n{n}.k{k}",
                         round(warm * 1e6, 1), tag))
            summary[(name, n)] = (cold, warm)
    if not summary:                    # every size below k: no topk leg
        return rows
    n_max = max(n for n in sizes if n >= k)
    sc, sw = summary[("topk_select", n_max)]
    fc, fw = summary[("topk_sort", n_max)]
    rows.append((f"engine.topk_select_vs_sort_warm_speedup.n{n_max}.k{k}",
                 0.0, round(fw / sw, 2)))
    rows.append((f"engine.topk_select_vs_sort_cold_speedup.n{n_max}.k{k}",
                 0.0, round(fc / sc, 2)))
    # measured crossover: largest n where sort-prefix still wins warm
    cross = [n for n in sizes if n >= k
             and summary[("topk_sort", n)][1] < summary[("topk_select", n)][1]]
    rows.append((f"engine.topk_crossover.k{k}", 0.0,
                 f"sort_wins_to_n={max(cross) if cross else 0}"))
    return rows


def run_topk_distributed(sizes=DEFAULT_SIZES, k=TOPK_K):
    """Mesh top-k: candidate all-gather vs the local select on the
    gathered array; empty on 1-device hosts."""
    import jax
    import jax.numpy as jnp
    from repro.engine import samplesort

    n_dev = len(jax.devices())
    if n_dev < 2:
        return []
    mesh = jax.make_mesh((n_dev,), ("data",))
    rows = []
    rng = np.random.default_rng(0)
    for n in sizes:
        if n < k:
            continue
        x = jnp.asarray(rng.standard_normal(n), jnp.float32)
        reps = 3 if n <= 65536 else 1
        cold, warm = _time_cold_warm_eager(
            lambda v: samplesort.sample_topk(v, k, mesh, "data"), x, reps)
        rows.append((f"engine.topk_dist.cold_ms.n{n}.k{k}",
                     round(cold * 1e3, 1), f"D={n_dev}"))
        rows.append((f"engine.topk_dist.warm_us.n{n}.k{k}",
                     round(warm * 1e6, 1), f"D={n_dev}"))
    return rows


def run_distributed(sizes=DEFAULT_SIZES):
    """sample vs oddeven (flat mesh) plus the two-level hierarchical
    schedule on a 2 x D/2 grid; empty on 1-device hosts."""
    import jax
    import jax.numpy as jnp
    from repro.core import distributed_sort as ds, topology
    from repro.engine import planner

    n_dev = len(jax.devices())
    if n_dev < 2:
        return []
    mesh = jax.make_mesh((n_dev,), ("data",))
    # hierarchical leg: a 2 x (D/2) grid when the device count allows —
    # on one host both tiers are the same physical link, so the wall
    # times measure schedule overhead, not the DCN win (the crossover
    # table in README comes from the cost model at real tier rates)
    mesh2 = jax.make_mesh((2, n_dev // 2), ("host", "dev")) \
        if n_dev >= 4 and n_dev % 2 == 0 else None
    rows, summary = [], {}
    rng = np.random.default_rng(0)
    for n in sizes:
        n -= n % n_dev                     # oddeven needs divisibility
        x = jnp.asarray(rng.standard_normal(n), jnp.float32)
        reps = 3 if n <= 65536 else 1
        for strat in ("oddeven", "sample"):
            cold, warm = _time_cold_warm_eager(
                lambda v, s=strat: ds.distributed_sort(v, mesh, strategy=s),
                x, reps)
            rows.append((f"engine.dist_{strat}.cold_ms.n{n}",
                         round(cold * 1e3, 1), f"D={n_dev}"))
            rows.append((f"engine.dist_{strat}.warm_us.n{n}",
                         round(warm * 1e6, 1), f"D={n_dev}"))
            summary[(strat, n)] = (cold, warm)
        if mesh2 is not None:
            cold, warm = _time_cold_warm_eager(
                lambda v: ds.distributed_sort(v, mesh2, strategy="hier"),
                x, reps)
            rows.append((f"engine.dist_hier.cold_ms.n{n}",
                         round(cold * 1e3, 1), f"D=2x{n_dev // 2}"))
            rows.append((f"engine.dist_hier.warm_us.n{n}",
                         round(warm * 1e6, 1), f"D=2x{n_dev // 2}"))
        auto = planner.choose_distributed(n, n_dev).strategy
        rows.append((f"engine.dist_auto.n{n}", 0.0, f"{n}:{auto}"))
        if mesh2 is not None:
            # the strategy the 2-tier mesh would actually run: odd-even
            # is single-axis-only, so it is out of the running here
            # (same filter distributed_sort applies on auto)
            topo = topology.for_mesh(mesh2)
            costs = planner.choose_distributed(n, n_dev,
                                               topology=topo).costs
            usable = {s: c for s, c in costs.items() if s != "oddeven"}
            rows.append((f"engine.dist_auto_2tier.n{n}", 0.0,
                         f"{n}:{min(usable, key=usable.__getitem__)}"))
    n_max = max(n - n % n_dev for n in sizes)
    oc, ow = summary[("oddeven", n_max)]
    sc, sw = summary[("sample", n_max)]
    rows.append((f"engine.dist_sample_vs_oddeven_warm_speedup.n{n_max}",
                 0.0, round(ow / sw, 2)))
    rows.append((f"engine.dist_sample_vs_oddeven_cold_speedup.n{n_max}",
                 0.0, round(oc / sc, 2)))
    return rows


# the naive join materialises an (n_l, n_r) equality matrix — quadratic,
# so its leg (and the sorted join it anchors) is capped
REL_JOIN_CAP = 4096


def run_relational(sizes=DEFAULT_SIZES):
    """Relational ops vs their naive XLA one-liners.

    Rows per n (dup-heavy int32 keys, ~n/4 distinct):

      * ``rel_unique``   vs ``jnp.unique(size=n)`` (scatter-heavy lowering)
      * ``rel_group_by`` (sum) vs unique+segment_sum composed directly
      * ``rel_join``     vs the dense O(n_l*n_r) equality-matrix nonzero,
                         both capped at n=4096

    The summary rows record the warm speedup of each op over its naive
    formulation at the largest n — the README "Relational kernels" numbers.
    """
    import jax
    import jax.numpy as jnp
    from repro import relational as rel

    rows, summary = [], {}
    rng = np.random.default_rng(0)
    for n in sizes:
        keys = jnp.asarray(rng.integers(0, max(2, n // 4), n), jnp.int32)
        vals = jnp.asarray(rng.integers(0, 100, n), jnp.int32)
        reps = 3 if n <= 65536 else 1

        def naive_unique(v):
            return jnp.unique(v, size=n, fill_value=0)

        def naive_group(v):
            u, inv = jnp.unique(keys, size=n, fill_value=0,
                                return_inverse=True)
            return u, jax.ops.segment_sum(v, inv, num_segments=n)

        legs = [
            ("rel_unique", lambda v: rel.unique(v).values, naive_unique,
             keys),
            ("rel_group_by",
             lambda v: rel.group_by(keys, v, agg="sum").aggregates[0],
             naive_group, vals),
        ]
        if n <= REL_JOIN_CAP:
            nj = n
            lk, rk = keys, jnp.asarray(
                rng.integers(0, max(2, n // 4), n), jnp.int32)
            pair_cap = 16 * nj

            def naive_join(l):
                return jnp.nonzero(l[:, None] == rk[None, :],
                                   size=pair_cap, fill_value=-1)

            legs.append(
                ("rel_join",
                 lambda l: rel.join(l, rk, size=pair_cap)[:2],
                 naive_join, lk))
        for name, fn, naive, x in legs:
            cold, warm = _time_cold_warm(fn, x, reps)
            ncold, nwarm = _time_cold_warm(naive, x, reps)
            rows.append((f"engine.{name}.cold_ms.n{n}",
                         round(cold * 1e3, 1), n))
            rows.append((f"engine.{name}.warm_us.n{n}",
                         round(warm * 1e6, 1), n))
            rows.append((f"engine.{name}_naive.warm_us.n{n}",
                         round(nwarm * 1e6, 1), n))
            summary[(name, n)] = (warm, nwarm)
    for name in ("rel_unique", "rel_group_by", "rel_join"):
        ns = [n for (b, n) in summary if b == name]
        if not ns:
            continue
        w, nw = summary[(name, max(ns))]
        rows.append((f"engine.{name}_vs_naive_warm_speedup.n{max(ns)}",
                     0.0, round(nw / w, 2)))
    return rows


# the spill leg forces device chunks of this many KEY BYTES, so modest
# bench sizes exercise the real pipeline shape (many chunks + host merge)
SPILL_CHUNK_BYTES = 256 << 10


def run_spill(sizes=DEFAULT_SIZES):
    """Out-of-core tier: measured overlap-on vs overlap-off, plus dedup.

    Rows per n (f32 keys, forced 256 KiB chunks so every size spans >= 4
    device chunks):

      * ``spill_sort``          the double-buffered pipeline (overlap on)
      * ``spill_sort_serial``   the same pipeline draining each chunk
                                before the next (overlap off)
      * ``spill_overlap_speedup``  serial/overlapped warm ratio — the
                                acceptance metric: > 1 means the H2D/D2H
                                link time is hidden behind chunk sorts.
                                On hosts whose "device" is the CPU itself
                                (CI) transfers are zero-copy, there is no
                                link time to hide, and the honest value
                                sits at ~1.0; the gap opens on discrete
                                accelerators where D2H is a real DMA.
      * ``spill_dedup``         data/pipeline.global_dedup over n token
                                rows (the tier's first consumer)

    All legs are eager/host-driven, so cold==first call and warm is the
    per-call mean, like the distributed rows.
    """
    from repro.data import pipeline as data_pipeline
    from repro.engine import spill

    rows = []
    rng = np.random.default_rng(0)
    for n in sizes:
        x = rng.standard_normal(n).astype(np.float32)
        if x.nbytes < 4 * SPILL_CHUNK_BYTES:
            continue                   # under 4 chunks the leg measures noise
        reps = 3 if n <= 65536 else 1
        timing = {}
        for name, overlap in (("spill_sort", True),
                              ("spill_sort_serial", False)):
            cold, warm = _time_cold_warm_eager(
                lambda v, o=overlap: spill.spill_sort(
                    v, chunk_bytes=SPILL_CHUNK_BYTES, overlap=o), x, reps)
            rows.append((f"engine.{name}.cold_ms.n{n}",
                         round(cold * 1e3, 1), n))
            rows.append((f"engine.{name}.warm_us.n{n}",
                         round(warm * 1e6, 1), n))
            timing[name] = warm
        rows.append((f"engine.spill_overlap_speedup.n{n}", 0.0,
                     round(timing["spill_sort_serial"]
                           / timing["spill_sort"], 2)))
    # dedup consumer at a fixed shape: rows, not elements, set the scale
    n_rows, seq = 4096, 64
    toks = rng.integers(0, 50, (n_rows, seq)).astype(np.int32)
    toks[rng.integers(0, n_rows, n_rows // 4)] = toks[0]   # planted dups
    cold, warm = _time_cold_warm_eager(
        lambda t: data_pipeline.global_dedup(t, chunk_bytes=4096),
        toks, 1)
    rows.append((f"engine.spill_dedup.cold_ms.rows{n_rows}",
                 round(cold * 1e3, 1), f"seq{seq}"))
    rows.append((f"engine.spill_dedup.warm_us.rows{n_rows}",
                 round(warm * 1e6, 1), f"seq{seq}"))
    return rows


def run(sizes=DEFAULT_SIZES):
    import jax
    import jax.numpy as jnp
    from repro import engine
    from repro.core import sort_api

    rows = []
    rng = np.random.default_rng(0)
    summary = {}
    backends = [
        ("xla", lambda v: sort_api.sort(v, method="xla")),
        ("pallas_bitonic", lambda v: sort_api.sort(v, method="pallas")),
        ("merge", lambda v: engine.sort(v, method="merge")),
        ("radix", lambda v: sort_api.sort(v, method="radix")),
        ("auto", lambda v: engine.sort(v, method="auto")),
    ]
    interp = jax.default_backend() != "tpu"
    for n in sizes:
        x = jnp.asarray(rng.standard_normal((1, n)), jnp.float32)
        reps = 3 if n <= 65536 else 1
        for name, fn in backends:
            if name == "radix" and interp and n > RADIX_INTERPRET_CAP:
                continue
            cold, warm = _time_cold_warm(fn, x, reps)
            tag = (f"{n}:{engine.choose_method(n, 1)}" if name == "auto"
                   else n)
            rows.append((f"engine.{name}.cold_ms.n{n}",
                         round(cold * 1e3, 1), tag))
            rows.append((f"engine.{name}.warm_us.n{n}",
                         round(warm * 1e6, 1), tag))
            summary[(name, n)] = (cold, warm)

    n_max = max(sizes)
    mc, mw = summary[("merge", n_max)]
    pc, pw = summary[("pallas_bitonic", n_max)]
    rows.append((f"engine.merge_vs_pallas_cold_speedup.n{n_max}",
                 0.0, round(pc / mc, 2)))
    rows.append((f"engine.merge_vs_pallas_warm_speedup.n{n_max}",
                 0.0, round(pw / mw, 2)))
    radix_ns = [n for (b, n) in summary if b == "radix"]
    if radix_ns:      # every size may exceed the interpret-mode cap
        rn = max(radix_ns)
        _, rw = summary[("radix", rn)]
        rows.append((f"engine.radix_vs_xla_warm_speedup.n{rn}",
                     0.0, round(summary[("xla", rn)][1] / rw, 2)))
        rows.append((f"engine.radix_vs_merge_warm_speedup.n{rn}",
                     0.0, round(summary[("merge", rn)][1] / rw, 2)))
    rows.extend(run_topk(sizes))
    rows.extend(run_relational(sizes))
    rows.extend(run_spill(sizes))
    rows.extend(run_distributed(sizes))
    rows.extend(run_topk_distributed(sizes))
    return rows


CSV_HEADER = "name,us_per_call,derived"


def write_csv(rows, path) -> None:
    """Append rows to ``path``, writing the header line exactly once.

    Successive runs append to one trajectory file, so the header is only
    emitted when the file is new/empty — and any header lines that earlier
    tooling did append mid-file are dropped on the way through.
    """
    import pathlib
    p = pathlib.Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    existing = p.read_text() if p.exists() else ""
    lines = [ln for ln in existing.splitlines() if ln and ln != CSV_HEADER]
    out = [CSV_HEADER] + lines + \
        [",".join(str(x) for x in row) for row in rows]
    p.write_text("\n".join(out) + "\n")


def main() -> None:
    import argparse
    import os
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="sweep up to 4M elements")
    ap.add_argument("--sizes", default="",
                    help="comma-separated n values (overrides presets)")
    ap.add_argument("--devices", type=int, default=0,
                    help="simulate N host-platform devices for the "
                         "distributed rows (must be set before jax loads)")
    ap.add_argument("--out", default="",
                    help="also append rows to this CSV (header deduped)")
    ap.add_argument("--json-out", default="",
                    help="also emit the canonical BENCH_sort.json artifact "
                         "(benchmarks/emit_bench.py) at the same sizes")
    args = ap.parse_args()
    if args.devices > 1:
        # only effective if jax has not initialised yet — that is why every
        # jax import in this module lives inside the run functions
        flag = f"--xla_force_host_platform_device_count={args.devices}"
        os.environ["XLA_FLAGS"] = \
            f"{os.environ.get('XLA_FLAGS', '')} {flag}".strip()
    if args.sizes:
        sizes = tuple(int(s) for s in args.sizes.split(","))
    else:
        sizes = FULL_SIZES if args.full else DEFAULT_SIZES
    rows = run(sizes)
    print(CSV_HEADER)
    for row in rows:
        print(",".join(str(x) for x in row))
    if args.out:
        write_csv(rows, args.out)
    if args.json_out:
        from benchmarks import emit_bench
        path = emit_bench.write(emit_bench.collect(sizes), args.json_out)
        print(f"# wrote {path}")


if __name__ == "__main__":
    main()
