"""Benchmark harness — one module per paper table/figure plus system-level
benches.  Prints ``name,us_per_call,derived`` CSV.

  PYTHONPATH=src python -m benchmarks.run [--only paper|sort|system]
"""
import argparse
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="")
    args = ap.parse_args()
    from benchmarks import bench_engine, bench_paper_tables, \
        bench_sort_methods, bench_system
    suites = {
        "paper": bench_paper_tables.run,
        "sort": bench_sort_methods.run,
        "system": bench_system.run,
        "engine": bench_engine.run,
    }
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites.items():
        if args.only and name != args.only:
            continue
        try:
            for row in fn():
                print(",".join(str(x) for x in row))
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{name}.SUITE_FAILED,0,{type(e).__name__}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == '__main__':
    main()
